"""Systolic matmul kernel vs the pure-jnp oracle (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.systolic import ops as K
from repro.kernels.systolic.ref import matmul_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128),        # single block
    (256, 384, 512),        # multi-block divisible
    (8, 128, 128),          # minimum sublane
    (100, 130, 70),         # non-divisible edges (padding path)
    (33, 257, 129),         # awkward primes
    (512, 128, 1024),       # deep contraction
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, n, k, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * n + k))
    a = jax.random.normal(ka, (m, k), dtype)
    b = jax.random.normal(kb, (k, n), dtype)
    got = K.matmul(a, b, interpret=True)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("activation", ["none", "relu", "gelu", "silu"])
def test_fused_bias_activation(activation):
    ka, kb, kc = jax.random.split(jax.random.PRNGKey(7), 3)
    a = jax.random.normal(ka, (64, 96), jnp.float32)
    b = jax.random.normal(kb, (96, 160), jnp.float32)
    bias = jax.random.normal(kc, (160,), jnp.float32)
    got = K.matmul(a, b, bias, activation=activation, interpret=True)
    want = matmul_ref(a, b, bias, activation=activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_explicit_block_plan():
    from repro.core.blocking import BlockPlan

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    plan = BlockPlan(256, 256, 256, 128, 128, 128)
    got = K.matmul(a, b, plan=plan, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), rtol=1e-4, atol=1e-4)


def test_out_dtype_override():
    a = jnp.ones((16, 128), jnp.bfloat16)
    b = jnp.ones((128, 128), jnp.bfloat16)
    got = K.matmul(a, b, out_dtype=jnp.float32, interpret=True)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), 128.0)


def test_shape_errors():
    a = jnp.ones((4, 8))
    with pytest.raises(ValueError):
        K.matmul(a, jnp.ones((9, 4)))
    with pytest.raises(ValueError):
        K.matmul(jnp.ones((4,)), jnp.ones((4, 4)))
