"""Grouped (per-expert) matmul kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped import ops as K
from repro.kernels.grouped.ref import grouped_matmul_ref


@pytest.mark.parametrize("e,c,k,n", [
    (4, 128, 128, 128),
    (8, 64, 96, 160),     # padding path
    (2, 8, 128, 128),
    (3, 100, 70, 130),    # non-divisible everywhere
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_shapes_dtypes(e, c, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(e * c + n))
    x = jax.random.normal(kx, (e, c, k), dtype)
    w = jax.random.normal(kw, (e, k, n), dtype)
    got = K.grouped_matmul(x, w, interpret=True)
    want = grouped_matmul_ref(x, w)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_experts_independent():
    """Zeroing one expert's weights must zero only its slice."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 48), jnp.float32)
    w = w.at[1].set(0.0)
    y = K.grouped_matmul(x, w, interpret=True)
    assert np.allclose(np.asarray(y[1]), 0.0)
    assert not np.allclose(np.asarray(y[0]), 0.0)


def test_shape_errors():
    with pytest.raises(ValueError):
        K.grouped_matmul(jnp.ones((2, 4, 8)), jnp.ones((3, 8, 4)))
    with pytest.raises(ValueError):
        K.grouped_matmul(jnp.ones((2, 4, 8)), jnp.ones((2, 9, 4)))
