"""Flash attention kernel + chunked lax attention vs the naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import ops as K
from repro.kernels.attention.ref import attention_ref
from repro.models.attention import chunked_mha


def _qkv(bh, sq, skv, d, dtype=jnp.float32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (bh, sq, d), dtype)
    k = jax.random.normal(k2, (bh, skv, d), dtype)
    v = jax.random.normal(k3, (bh, skv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("sq,skv,causal,window", [
    (128, 128, True, None),
    (256, 256, True, None),
    (128, 256, False, None),   # cross-attention style
    (256, 256, True, 64),      # sliding window
    (100, 200, True, None),    # padding path
    (128, 128, True, 32),      # window smaller than block
    (100, 100, True, 48),      # SWA + bq/bkv-non-divisible lengths: the
                               # padded-KV tail must stay masked while the
                               # window mask trims the other side
    (190, 190, True, 64),      # SWA + padding, window crosses block edges
    (130, 230, True, 32),      # SWA + non-divisible + longer KV stream
])
def test_flash_vs_ref(sq, skv, causal, window):
    q, k, v = _qkv(2, sq, skv, 64)
    got = K.flash_attention(
        q[:, None].transpose(0, 1, 2, 3).reshape(2, 1, sq, 64),
        k.reshape(2, 1, skv, 64),
        v.reshape(2, 1, skv, 64),
        causal=causal,
        window=window,
        bq=128,
        bkv=128,
        interpret=True,
    ).reshape(2, sq, 64)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _qkv(4, 128, 128, 64, dtype)
    got = K.flash_attention(
        q.reshape(2, 2, 128, 64), k.reshape(2, 2, 128, 64),
        v.reshape(2, 2, 128, 64), causal=True, interpret=True,
    ).reshape(4, 128, 64)
    want = attention_ref(q, k, v, causal=True)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


@pytest.mark.parametrize("s,t,window,bq,bkv", [
    (64, 64, None, 16, 16),
    (100, 100, None, 32, 64),   # padding
    (128, 128, 48, 32, 32),     # window
    (96, 96, None, 96, 96),     # single block
    (100, 100, 48, 32, 64),     # SWA + non-divisible lengths (padded KV)
    (90, 170, 40, 64, 64),      # SWA + non-divisible + longer KV stream
])
def test_chunked_mha_vs_ref(s, t, window, bq, bkv):
    """The lax.scan flash (what 32k-prefill cells lower) is exact."""
    q, k, v = _qkv(2, s, t, 32, seed=3)
    got = chunked_mha(
        q.reshape(2, s, 1, 32).transpose(0, 1, 2, 3),
        k.reshape(2, t, 1, 32),
        v.reshape(2, t, 1, 32),
        causal=True, window=window, bq=bq, bkv=bkv,
    )[:, :, 0]
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_chunked_mha_mla_head_dims():
    """v head dim != qk head dim (the MLA prefill case)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(k1, (2, 64, 4, 48))
    k = jax.random.normal(k2, (2, 64, 4, 48))
    v = jax.random.normal(k3, (2, 64, 4, 32))
    got = chunked_mha(q, k, v, causal=True, bq=16, bkv=16)
    # oracle per head
    outs = []
    for h in range(4):
        outs.append(attention_ref(
            q[:, :, h], k[:, :, h], v[:, :, h], causal=True,
            scale=48**-0.5,
        ))
    want = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
